"""Fleet-scale serving benchmark: shared-cloud tail batching + planner
re-solve speed.

Claims checked by assertion (so ``benchmarks.run`` fails loudly if they
regress):

1. **Shared-cloud tail batching pays.** With N >= 4 edge devices in
   flight on the same (point, bits, codec) plan, the shared cloud's one
   batched wire decode + one concatenated tail forward
   (``DecoupledRunner.cloud_step_batch(fuse_tail=True)``) beats running
   the per-request ``cloud_step`` N times by a measured margin — the
   same dispatch-amortization argument as PR 3's micro-batched edge
   encode, now on the cloud half (plus real compute batching: one
   wide-batch tail utilizes the cores far better than N narrow
   forwards — measured 3.4x at a mid-network cut). Benchmarked on a
   pinned device-codec plan (mid-network bitpack), because the
   degenerate case — a host-entropy codec whose decode can't batch, cut
   at the last layer so there is almost no tail — has nothing to
   amortize by construction. The bit-exact default mode (batched
   decode, per-request tails) is timed alongside and must return
   byte-identical logits.

2. **Planner re-solve is >= 10x faster than rebuilding.** One full
   adaptation re-decision under a new bandwidth — the candidate solve
   plus the hysteresis cost of keeping the old plan — through
   ``PlanSpace.decide`` + ``PlanSpace.plan_cost`` (fused argmin over
   precomputed operands + an O(1) row lookup) must be at least 10x
   faster than the pre-planner path, reproduced verbatim: rebuild the
   ``ILPProblem`` from scratch (cumsum over the FMAC profile, per-point
   ``exec_time`` python loops for both device vectors, table reshapes,
   enumeration solve, plan materialization) plus the old
   ``AdaptationController._plan_cost`` duplicate, which recomputed both
   uncached latency vectors again. Asserted at the paper-scale decision
   grid (N=50 points x 16 bit widths x 3 codecs, the ``ilp_solve_time``
   sizing); the small fleet-engine grid is reported alongside.

3. **Fleet-wide re-planning scales sublinearly in D.** One fleet
   re-decision round — ``FleetAdaptationController.current_plans``, i.e.
   the fused ``FleetPlanSpace.decide_all`` argmin plus the vectorized
   hysteresis commit — re-plans a 10^3 / 10^4 / 10^5-device fleet at the
   paper-scale decision grid; round time must grow strictly sublinearly
   in the device count (growing the fleet 100x must cost < 0.9 * 100x —
   the round's fixed dispatch overhead amortizes as D grows) and the
   per-device re-decision overhead at D = 10^5 must stay under a fixed
   budget. A random sample of devices is pinned bitwise against the
   per-device ``with_edge(p).decide(bw)`` oracle on every run (the full
   randomized pin lives in tests/test_fleet_planner.py).

4. **Three-tier fleet re-planning stays cheap.** One
   ``TriFleetAdaptationController`` round — the fused
   ``TriFleetPlanSpace.decide_all`` over the Pareto-kept two-cut cells
   with per-device (BW1, BW2) pairs, plus the vectorized hysteresis
   commit — must stay within a fixed per-device budget at the
   paper-scale grid and D = 10^5 devices. A random device sample is
   spot-pinned against the scalar two-cut oracle
   (``TriPlanSpace.decide`` on a per-device view).

Also reports the end-to-end fleet numbers (makespan vs the fully
sequential sum of service times) for the N-device round-robin stream.
"""
from __future__ import annotations

import time
from typing import Dict

import numpy as np

from benchmarks.common import fmt_table
from repro.config import JaladConfig, get_config
from repro.config.types import EDGE_TK1, EDGE_TX2, DeviceProfile
from repro.core.decoupler import DecoupledPlan
from repro.core.ilp import ILPProblem, solve_enumeration
from repro.core.latency import LatencyModel
from repro.data.synthetic import make_batch
from repro.serving.fleet import build_fleet_server
from repro.serving.workloads import make_trace

PROFILES = [
    EDGE_TX2,
    EDGE_TK1,
    DeviceProfile("edge-mid", 1e12, 1.30),
    DeviceProfile("edge-fast", 4e12, 0.90),
]
CLOUD_BATCH_MARGIN = 1.15      # batched cloud must be >= 15% faster
REPLAN_SPEEDUP_MIN = 10.0      # planner re-solve vs ILPProblem rebuild
FLEET_SIZES = (1_000, 10_000, 100_000)
FLEET_SUBLINEAR_MARGIN = 0.9   # 100x devices must cost < 0.9 * 100x time
FLEET_BUDGET_US = 2.0          # per-device re-decision budget at D = 1e5
TRI_FLEET_BUDGET_US = 10.0     # three-tier per-device budget at D = 1e5
TRI_FLEET_SIZES = (10_000, 100_000)
TRI_FLEET_ORACLE_SAMPLE = 4    # scalar-oracle spot-pins (finalize is heavy)
FLEET_ORACLE_SAMPLE = 16       # devices spot-checked against with_edge
FLEET_DRIFT_ROUNDS = 6         # distinct bandwidth vectors cycled per size
FLEET_TIMING_REPS = 20         # interleaved best-of reps per size
REPEATS = 5


def _best_of(fn, repeats=REPEATS):
    best = np.inf
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best, out


def _legacy_decide(engine, bw: float):
    """The pre-planner decision path, reproduced verbatim: every bandwidth
    drift rebuilt the latency vectors (cumsum + per-point exec_time python
    loops — a fresh LatencyModel models the old cache-less recompute) and
    the full ILPProblem, ran the enumeration solve, and materialized the
    plan from the solution."""
    lat = engine.latency
    fresh = LatencyModel(lat.fmacs_per_point, lat.edge, lat.cloud,
                         lat.input_bytes)
    rows = engine.point_indices or list(range(len(engine.tables.points)))
    te = fresh.edge_times()[rows]
    tc = fresh.cloud_times()[rows]
    n = engine.tables.size_bytes.shape[0]
    ttrans = engine.tables.size_bytes.reshape(n, -1) / float(bw)
    cost = te[:, None] + tc[:, None] + ttrans
    sol = solve_enumeration(
        ILPProblem(cost, engine.tables.acc_drop.reshape(n, -1),
                   engine.cfg.accuracy_drop_budget)
    )
    if sol is None:
        return None
    rows = engine.point_indices or list(range(len(engine.tables.points)))
    ci, ki = divmod(sol.bits_index, len(engine.tables.codecs))
    return DecoupledPlan(
        point=rows[sol.point],
        bits=engine.tables.bits_choices[ci],
        predicted_latency=sol.objective,
        predicted_acc_drop=float(engine.tables.acc_drop[sol.point, ci, ki]),
        solve_ms=sol.solve_ms,
        codec=engine.tables.codecs[ki],
    )


def _legacy_plan_cost(engine, plan, bw: float) -> float:
    """The deleted ``AdaptationController._plan_cost`` duplicate, verbatim
    — including the two full latency-vector recomputations it triggered
    through the old cache-less LatencyModel on every hysteresis check."""
    lat = engine.latency
    fresh = LatencyModel(lat.fmacs_per_point, lat.edge, lat.cloud,
                         lat.input_bytes)
    if plan.is_cloud_only:
        return fresh.cloud_only_time(bw)
    rows = engine.point_indices or list(range(len(engine.tables.points)))
    row = rows.index(plan.point)
    c = engine.tables.bits_choices.index(plan.bits)
    k = engine.tables.codec_index(plan.codec)
    return (
        fresh.edge_times()[plan.point]
        + engine.tables.size_bytes[row, c, k] / bw
        + fresh.cloud_times()[plan.point]
    )


def _paper_scale_engine():
    """A decision problem at the paper's sizing (N=50 decoupling points,
    16 bit widths, 3 codecs — cf. ``benchmarks/ilp_solve_time``): the
    model is irrelevant to the decision plane, so tables are synthetic."""
    from repro.config.types import CLOUD_1080TI
    from repro.core.decoupler import JaladEngine
    from repro.core.predictor import PredictorTables

    rng = np.random.default_rng(7)
    n, c, k = 50, 16, 3
    bits = tuple(range(1, c + 1))
    codecs = ("huffman", "bitpack", "perchannel")
    tables = PredictorTables(
        points=[f"p{i}" for i in range(n)],
        bits_choices=list(bits),
        codecs=list(codecs),
        acc_drop=rng.random((n, c, k)) * 0.3,
        size_bytes=rng.random((n, c, k)) * 1e6 + 1e3,
        base_accuracy=0.9,
    )
    lat = LatencyModel(rng.random(n) * 2e9 + 1e8, EDGE_TX2, CLOUD_1080TI,
                       input_bytes=150_528.0)
    cfg = JaladConfig(bits_choices=bits, codec_choices=codecs,
                      accuracy_drop_budget=0.15)
    return JaladEngine(None, tables, lat, cfg)


def run(quick: bool = True) -> Dict:
    n_per_device = 2 if quick else 6
    cfg = get_config("resnet50").reduced()
    jc = JaladConfig(bits_choices=(2, 4, 8), accuracy_drop_budget=0.10,
                     bandwidth_bytes_per_s=1e6)
    fleet, params = build_fleet_server(
        cfg, jc, PROFILES, calib_batches=1, calib_batch_size=4)
    engine = fleet.engine
    results: Dict = {"devices": [p.name for p in PROFILES]}

    # ---------------------------------------- 1. shared-cloud tail batching
    # A representative fleet plan: mid-network cut, device-side bitpack
    # codec — the case the shared cloud worker exists for (substantial
    # tail, one-launch batched decode). The ILP's own pick at 1 MB/s is
    # often (last layer, huffman): tiny tail + loop-decoded host codec,
    # which has nothing to amortize by construction.
    mid_row = min(4, len(engine.plan_space.point_rows) - 1)
    plan = DecoupledPlan(engine.plan_space.point_rows[mid_row], 4,
                         0.0, 0.0, 0.0, codec="bitpack")
    runner = fleet.runners.get(plan)
    n_flight = len(PROFILES) * n_per_device
    blobs = [runner.edge_step(make_batch(cfg, 4, 0, seed=300 + i))[0]
             for i in range(n_flight)]

    def per_request():
        outs = [runner.cloud_step(b) for b in blobs]
        outs[-1].block_until_ready()
        return outs

    def batched_exact():
        outs = runner.cloud_step_batch(blobs)
        outs[-1].block_until_ready()
        return outs

    def batched_fused():
        outs = runner.cloud_step_batch(blobs, fuse_tail=True)
        outs[-1].block_until_ready()
        return outs

    per_request()                          # warm up (jit all paths)
    batched_exact()
    batched_fused()
    t_loop, ref = _best_of(per_request, repeats=3)
    t_exact, out_exact = _best_of(batched_exact, repeats=3)
    t_fused, out_fused = _best_of(batched_fused, repeats=3)
    for a, b in zip(ref, out_exact):       # exact mode: byte-identical
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(ref, out_fused):       # fused mode: float-equivalent
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-5)
    ratio = t_loop / t_fused
    results["cloud_batching"] = {
        "in_flight": n_flight,
        "plan": [plan.point, plan.bits, plan.codec],
        "per_request_ms": t_loop * 1e3,
        "batched_exact_ms": t_exact * 1e3,
        "batched_fused_ms": t_fused * 1e3,
        "fused_speedup_x": ratio,
        "exact_speedup_x": t_loop / t_exact,
    }
    print(f"\nShared-cloud tail, {n_flight} in-flight requests on plan "
          f"(i={plan.point}, c={plan.bits}, {plan.codec})")
    print(fmt_table(
        [[f"{t_loop * 1e3:.2f}ms", f"{t_exact * 1e3:.2f}ms",
          f"{t_fused * 1e3:.2f}ms", f"{ratio:.2f}x"]],
        [f"{n_flight}x cloud_step", "batched (bit-exact)",
         "batched (fused tail)", "fused speedup"]))
    assert ratio >= CLOUD_BATCH_MARGIN, (
        f"batched shared-cloud tail must be >= {CLOUD_BATCH_MARGIN}x faster "
        f"than per-request cloud steps at N={len(PROFILES)} devices, got "
        f"{ratio:.2f}x"
    )

    # ------------------------------------------- 2. planner re-solve speed
    rng = np.random.default_rng(0)
    bws = 10 ** rng.uniform(4.5, 7.5, size=64)

    def _measure_replan(eng):
        space = eng.plan_space

        def replan_all():
            # one full adaptation re-decision per drift: candidate solve
            # + hysteresis cost of keeping the previous plan
            prev = None
            out = []
            for bw in bws:
                cand = space.decide(bw)
                if prev is not None:
                    space.plan_cost(prev, bw)
                out.append(cand)
                prev = cand
            return out

        def rebuild_all():
            prev = None
            out = []
            for bw in bws:
                cand = _legacy_decide(eng, bw)
                if prev is not None:
                    _legacy_plan_cost(eng, prev, bw)
                out.append(cand)
                prev = cand
            return out

        replan_all()                       # warm (PlanSpace already built)
        rebuild_all()
        # best-of-9: both sides are sub-ms python loops, so take the least
        # noisy sample of each to keep the CI assert stable on shared
        # runners.
        t_fast, plans = _best_of(replan_all, repeats=9)
        t_slow, sols = _best_of(rebuild_all, repeats=9)
        # same decisions, same objectives — the fast path is a pure speedup
        for p, s in zip(plans, sols):
            if s is None:
                assert p.is_cloud_only
            else:
                assert p.predicted_latency == s.predicted_latency
                assert (p.point, p.bits, p.codec) == \
                    (s.point, s.bits, s.codec)
        return {
            "n_points": int(space.edge_vec.shape[0]),
            "n_choices": space.n_choices,
            "planner_us_per_solve": t_fast / len(bws) * 1e6,
            "rebuild_us_per_solve": t_slow / len(bws) * 1e6,
            "speedup_x": t_slow / t_fast,
        }

    fleet_replan = _measure_replan(engine)
    paper_replan = _measure_replan(_paper_scale_engine())
    results["replan"] = {"n_bandwidths": len(bws),
                         "fleet_engine": fleet_replan,
                         "paper_scale": paper_replan}
    rows = []
    for label, m in [("fleet engine", fleet_replan),
                     ("paper scale", paper_replan)]:
        rows.append([label, f"{m['n_points']}x{m['n_choices']}",
                     f"{m['planner_us_per_solve']:.1f}us",
                     f"{m['rebuild_us_per_solve']:.1f}us",
                     f"{m['speedup_x']:.1f}x"])
    print(f"\nRe-solve under {len(bws)} bandwidth drifts")
    print(fmt_table(rows, ["grid", "N x CK", "PlanSpace.decide",
                           "ILPProblem rebuild", "speedup"]))
    speedup = paper_replan["speedup_x"]
    assert speedup >= REPLAN_SPEEDUP_MIN, (
        f"planner re-solve must be >= {REPLAN_SPEEDUP_MIN}x faster than "
        f"rebuilding the ILPProblem at paper scale, got {speedup:.1f}x"
    )

    # ------------------------------------------------- 3. fleet scaling
    from repro.config.types import DeviceProfile as _DP
    from repro.core.adaptation import FleetAdaptationController
    from repro.core.planner import FleetPlanSpace

    space = _paper_scale_engine().plan_space
    rng = np.random.default_rng(11)
    # One fleet-wide re-decision = one controller round: the fused
    # decide_all plus the vectorized hysteresis commit over (D,) state —
    # exactly what the fleet server pays per wave. Timing rounds are
    # interleaved across fleet sizes (best-of-N each) so a noisy-neighbor
    # burst on a shared runner hits every size, not one of them.
    fleets = {}
    for n_dev in FLEET_SIZES:
        flops = rng.uniform(2e11, 5e12, n_dev)
        w = rng.uniform(0.8, 1.5, n_dev)
        fleet_space = FleetPlanSpace.build(space, flops=flops, w=w)
        drifts = [10 ** rng.uniform(4.5, 7.5, n_dev)
                  for _ in range(FLEET_DRIFT_ROUNDS)]
        ctrl = FleetAdaptationController(fleet_space)
        ctrl.current_plans(drifts[0])              # warm buffers + commit
        fleets[n_dev] = (fleet_space, ctrl, drifts, flops, w)
    times_s = {n: np.inf for n in FLEET_SIZES}
    t_decide = {n: np.inf for n in FLEET_SIZES}
    for rep in range(FLEET_TIMING_REPS):
        for n_dev, (fleet_space, ctrl, drifts, _, _) in fleets.items():
            bws_fleet = drifts[rep % len(drifts)]
            t0 = time.perf_counter()
            ctrl.current_plans(bws_fleet)
            times_s[n_dev] = min(times_s[n_dev], time.perf_counter() - t0)
            t0 = time.perf_counter()
            fleet_space.decide_all(bws_fleet)
            t_decide[n_dev] = min(t_decide[n_dev],
                                  time.perf_counter() - t0)
    scaling_rows = []
    for n_dev in FLEET_SIZES:
        scaling_rows.append([
            f"{n_dev:,}", f"{t_decide[n_dev] * 1e3:.2f}ms",
            f"{times_s[n_dev] * 1e3:.2f}ms",
            f"{times_s[n_dev] / n_dev * 1e6:.3f}us"])
        # spot-pin a random device sample against the scalar oracle
        fleet_space, _, drifts, flops, w = fleets[n_dev]
        decision = fleet_space.decide_all(drifts[0])
        for d in rng.choice(n_dev, size=FLEET_ORACLE_SAMPLE, replace=False):
            view = space.with_edge(
                _DP(f"bench-{d}", float(flops[d]), float(w[d])))
            ref = view.decide(float(drifts[0][d]))
            got = decision.plan(int(d))
            assert (got.point, got.bits, got.codec) == \
                (ref.point, ref.bits, ref.codec), (n_dev, d)
            assert got.predicted_latency == ref.predicted_latency, (n_dev, d)
    d_lo, d_hi = FLEET_SIZES[0], FLEET_SIZES[-1]
    growth = times_s[d_hi] / times_s[d_lo]
    allowed = FLEET_SUBLINEAR_MARGIN * (d_hi / d_lo)
    per_device_us = times_s[d_hi] / d_hi * 1e6
    results["fleet_scaling"] = {
        "grid": f"{space.edge_vec.shape[0]}x{space.n_choices}",
        "decide_all_ms": {str(n): t_decide[n] * 1e3 for n in FLEET_SIZES},
        "replan_round_ms": {str(n): times_s[n] * 1e3
                            for n in FLEET_SIZES},
        "growth_x": growth,
        "allowed_growth_x": allowed,
        "per_device_us_at_max": per_device_us,
        "oracle_sample_per_size": FLEET_ORACLE_SAMPLE,
    }
    print(f"\nFleet-wide re-plan (one adaptation round, paper-scale grid "
          f"{results['fleet_scaling']['grid']})")
    print(fmt_table(scaling_rows, ["devices", "decide_all",
                                   "replan round", "per device"]))
    print(f"{d_lo:,} -> {d_hi:,} devices: {growth:.1f}x time for "
          f"{d_hi // d_lo}x devices (sublinear bound {allowed:.0f}x), "
          f"{per_device_us:.3f}us/device at D={d_hi:,}")
    assert growth < allowed, (
        f"fleet re-decision time must grow sublinearly in D: "
        f"{d_hi // d_lo}x devices took {growth:.1f}x time "
        f"(bound {allowed:.0f}x)"
    )
    assert per_device_us <= FLEET_BUDGET_US, (
        f"per-device decision overhead at D={d_hi:,} must stay within "
        f"{FLEET_BUDGET_US}us, got {per_device_us:.3f}us"
    )

    # ------------------------------------------- 3b. three-tier re-plan
    from repro.core.adaptation import TriFleetAdaptationController
    from repro.core.tri_planner import TriFleetPlanSpace

    from benchmarks.table3_edge_power import replace_device

    tri = _paper_scale_engine().tri_space
    rng = np.random.default_rng(13)
    tri_times = {n: np.inf for n in TRI_FLEET_SIZES}
    tri_fleets = {}
    for n_dev in TRI_FLEET_SIZES:
        flops = rng.uniform(2e11, 5e12, n_dev)
        w = rng.uniform(0.8, 1.5, n_dev)
        tfs = TriFleetPlanSpace.build(tri, flops=flops, w=w)
        drifts = [(10 ** rng.uniform(4.5, 7.5, n_dev),
                   10 ** rng.uniform(5.5, 8.0, n_dev))
                  for _ in range(FLEET_DRIFT_ROUNDS)]
        ctrl = TriFleetAdaptationController(tfs)
        ctrl.current_plans(*drifts[0])             # warm buffers + commit
        tri_fleets[n_dev] = (tfs, ctrl, drifts, flops, w)
    for rep in range(FLEET_TIMING_REPS):
        for n_dev, (tfs, ctrl, drifts, _, _) in tri_fleets.items():
            b1, b2 = drifts[rep % len(drifts)]
            t0 = time.perf_counter()
            ctrl.current_plans(b1, b2)
            tri_times[n_dev] = min(tri_times[n_dev],
                                   time.perf_counter() - t0)
    tri_rows = []
    for n_dev in TRI_FLEET_SIZES:
        tri_rows.append([f"{n_dev:,}", f"{tri_times[n_dev] * 1e3:.2f}ms",
                         f"{tri_times[n_dev] / n_dev * 1e6:.3f}us"])
    # spot-pin a device sample against the scalar two-cut oracle
    tfs, _, drifts, flops, w = tri_fleets[TRI_FLEET_SIZES[0]]
    decision = tfs.decide_all(*drifts[0])
    for d in rng.choice(TRI_FLEET_SIZES[0], size=TRI_FLEET_ORACLE_SAMPLE,
                        replace=False):
        view = replace_device(
            tri, DeviceProfile(f"tri-{d}", float(flops[d]), float(w[d])))
        ref = view.decide(float(drifts[0][0][d]), float(drifts[0][1][d]))
        got = decision.plan(int(d))
        assert (got.point, got.bits, got.point2, got.bits2) == \
            (ref.point, ref.bits, ref.point2, ref.bits2), d
        assert got.predicted_latency == ref.predicted_latency, d
    tri_per_device_us = tri_times[TRI_FLEET_SIZES[-1]] \
        / TRI_FLEET_SIZES[-1] * 1e6
    results["tri_fleet_scaling"] = {
        "kept_cells": tfs.n_cells,
        "replan_round_ms": {str(n): tri_times[n] * 1e3
                            for n in TRI_FLEET_SIZES},
        "per_device_us_at_max": tri_per_device_us,
        "oracle_sample": TRI_FLEET_ORACLE_SAMPLE,
    }
    print(f"\nThree-tier fleet re-plan (two-cut grid, "
          f"{tfs.n_cells} Pareto-kept cells)")
    print(fmt_table(tri_rows, ["devices", "replan round", "per device"]))
    assert tri_per_device_us <= TRI_FLEET_BUDGET_US, (
        f"three-tier per-device re-plan at D={TRI_FLEET_SIZES[-1]:,} must "
        f"stay within {TRI_FLEET_BUDGET_US}us, got {tri_per_device_us:.3f}us"
    )

    # ----------------------------------------------- 4. end-to-end stream
    # Trace-shaped traffic instead of a hand-built round-robin list: a
    # steady-load trace with per-device bandwidth walks. dt_s is kept far
    # below the per-request service time so the arrival spread does not
    # dominate the makespan-vs-sequential comparison.
    trace = make_trace(len(PROFILES), n_steps=2 * n_per_device + 2,
                       seed=23, kind="steady", dt_s=1e-3, base_rate=0.85,
                       mean_bps=1e6, spread=2.0)
    reqs = trace.requests(lambda uid, d: make_batch(cfg, 4, 0,
                                                    seed=400 + uid))
    done = fleet.serve(reqs)
    results["stream"] = {
        "trace": {"kind": "steady", "seed": trace.seed,
                  "n_steps": trace.n_steps},
        "requests": len(done),
        "makespan_s": fleet.makespan_s,
        "sequential_s": fleet.synchronous_time_s(),
        "batched_cloud_launches": fleet.batched_launches(),
        "per_device_plans": [
            [dev.log[-1].plan_point, dev.log[-1].plan_bits,
             dev.log[-1].plan_codec]
            for dev in fleet.devices
        ],
    }
    print(f"\nFleet stream: {len(done)} requests over {len(PROFILES)} "
          f"devices -> makespan {fleet.makespan_s * 1e3:.1f}ms vs "
          f"sequential {fleet.synchronous_time_s() * 1e3:.1f}ms, "
          f"{fleet.batched_launches()} batched cloud launches")
    assert fleet.makespan_s < fleet.synchronous_time_s()
    assert fleet.batched_launches() >= 1

    return results


if __name__ == "__main__":
    run()
