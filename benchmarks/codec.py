"""Boundary-codec comparison: edge-encode / cloud-decode latency and wire
bytes for every registered codec at several bit widths.

The claim checked by assertion (so ``benchmarks.run`` fails loudly if it
regresses): the ``bitpack`` codec's *device-side* edge encode (one jitted
fused Pallas quantize+pack launch + host framing) is faster than the
``huffman`` codec's host path (quantize + pure-Python/numpy Huffman) at
equal bit width — the encode half of the codec no longer scales with the
host's entropy coder. Huffman keeps the smallest wire; the ILP trades
those two against the link bandwidth.
"""
from __future__ import annotations

import time
from typing import Dict

import numpy as np
import jax.numpy as jnp

from benchmarks.common import fmt_table, save_result
from repro.codec import get_codec, list_codecs

SHAPE_QUICK = (8, 32, 28, 28)        # ~200k elements, NCHW feature map
SHAPE_FULL = (16, 64, 56, 56)        # ~3.2M elements
BITS = (2, 4, 8)
REPEATS = 3


def _features(shape, seed=0):
    x = np.random.default_rng(seed).standard_normal(shape).astype(np.float32)
    x[np.abs(x) < 0.8] = 0.0          # post-ReLU-like sparsity
    return jnp.asarray(x)


def _best_of(fn, repeats=REPEATS):
    best = np.inf
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best, out


def run(quick: bool = True) -> Dict:
    shape = SHAPE_QUICK if quick else SHAPE_FULL
    x = _features(shape)
    rows = []
    results: Dict = {"shape": list(shape), "codecs": {}}
    encode_ms: Dict = {}
    for bits in BITS:
        for name in list_codecs():
            codec = get_codec(name)
            codec.encode(x, bits)            # warm up (jit compile)
            t_enc, blob = _best_of(lambda: codec.encode(x, bits))
            out = codec.decode(blob)
            out.block_until_ready()          # warm up decode
            t_dec, _ = _best_of(
                lambda: codec.decode(blob).block_until_ready()
            )
            encode_ms[(name, bits)] = t_enc * 1e3
            results["codecs"].setdefault(name, []).append({
                "bits": bits,
                "encode_ms": t_enc * 1e3,
                "decode_ms": t_dec * 1e3,
                "wire_bytes": blob.nbytes,
            })
            rows.append([
                f"c={bits}", name, f"{t_enc * 1e3:.2f}ms",
                f"{t_dec * 1e3:.2f}ms", f"{blob.nbytes:,}B",
                f"{x.size * 4 / blob.nbytes:.1f}x",
            ])
    print(f"\nBoundary codecs on {shape} float32 "
          f"({x.size * 4 / 1e6:.1f} MB raw)")
    print(fmt_table(rows, ["bits", "codec", "edge encode", "cloud decode",
                           "wire", "vs f32"]))
    for bits in BITS:
        assert encode_ms[("bitpack", bits)] < encode_ms[("huffman", bits)], (
            f"device-side bitpack encode ({encode_ms[('bitpack', bits)]:.2f}"
            f"ms) must beat host Huffman ({encode_ms[('huffman', bits)]:.2f}"
            f"ms) at c={bits}"
        )
    path = save_result("codec", results)
    print(f"wrote {path}")
    return results
