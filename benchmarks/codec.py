"""Boundary-codec comparison: edge-encode / cloud-decode latency, wire
bytes, pallas_call launch counts, and micro-batched encode throughput.

Claims checked by assertion (so ``benchmarks.run`` fails loudly if they
regress):

1. The ``bitpack`` codec's device-side edge encode beats the ``huffman``
   codec's host path (quantize + Huffman) at equal bit width.
2. The fused **single-launch** edge encode (hierarchical min/max
   reduction + quantize + pack in one two-phase pallas_call) is strictly
   faster than the PR 2 three-launch chain (minmax -> quantize -> pack4)
   at bits 4 and 8 — fewer dispatches and no codes round trip through
   HBM.
3. Launch accounting: fused encode = 1 pallas_call, PR 2 chain = 3
   (2 above 4 bits), per-channel fused encode = 1, and a B=8 micro-batch
   still = 1.
4. Micro-batched encode (B=8 same-shape boundary tensors, one stacked
   launch with per-sample ranges) achieves >= 2x the per-tensor encode
   throughput on serving-sized boundaries — the dispatch amortization
   the pipelined edge stage banks on.
5. The device-resident two-phase Huffman encode (histogram dispatch +
   fused quantize/LUT-gather/scan/pack kernel) is byte-identical to the
   host reference, runs in exactly 2 device dispatches per batch, and
   reaches >= 3x the throughput of the host per-tensor loop at B=8 on a
   paper-scale boundary tensor (``python -m benchmarks.codec --entropy``
   runs just this gate — the CI smoke).

Huffman keeps the smallest wire; the ILP trades encode cost against
transfer bytes.
"""
from __future__ import annotations

import time
from typing import Dict

import numpy as np
import jax.numpy as jnp

from benchmarks.common import fmt_table
from repro.codec import get_codec, list_codecs
from repro.kernels.quantize import ops

SHAPE_QUICK = (8, 32, 28, 28)        # ~200k elements, NCHW feature map
SHAPE_FULL = (16, 64, 56, 56)        # ~3.2M elements
MICRO_SHAPE = (2, 8, 14, 14)         # serving-sized boundary tensor
MICRO_B = 8
BITS = (2, 4, 8)
FUSED_BITS = (4, 8)
REPEATS = 3
ENTROPY_SHAPE = (64, 28, 28)         # paper-scale conv boundary map
ENTROPY_B = 8
# 4-bit is the paper's aggressive low-bit operating point, and the only
# one where symbol folding is data-independent (<= 16 symbols puts a
# hard 15-bit ceiling on canonical code lengths, so the kernel always
# folds symbol pairs regardless of the activation distribution).
ENTROPY_BITS = 4
ENTROPY_REPEATS = 7


def _features(shape, seed=0):
    x = np.random.default_rng(seed).standard_normal(shape).astype(np.float32)
    x[np.abs(x) < 0.8] = 0.0          # post-ReLU-like sparsity
    return jnp.asarray(x)


def _best_of(fn, repeats=REPEATS):
    best = np.inf
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best, out


def _launches(fn) -> int:
    """pallas_call dispatches of one eager (un-jitted impl) invocation."""
    with ops.count_launches() as c:
        fn()
    return c.count


def entropy_encode_section(quick: bool = True) -> Dict:
    """Gate 5: the device-resident two-phase batched Huffman encode.

    B=8 paper-scale boundary tensors (dense pre-activation statistics —
    a standard-normal conv feature map at c=4) against the host
    per-tensor loop (eager quantize + full code transfer + numpy
    bitstream build, i.e. what the codec did before the device path).
    Byte-identity and the 2-dispatch budget are asserted before any
    timing, so a silently-diverging stream can never "win" the gate.
    """
    codec = get_codec("huffman")
    rng = np.random.default_rng(5)
    xb = jnp.asarray(rng.standard_normal(
        (ENTROPY_B,) + ENTROPY_SHAPE).astype(np.float32))
    rows = [xb[i] for i in range(ENTROPY_B)]

    dev_blobs = codec.encode_batch(rows, ENTROPY_BITS)       # warm + jit
    host_blobs = [codec._encode_host(r, ENTROPY_BITS) for r in rows]
    for i, (db, hb) in enumerate(zip(dev_blobs, host_blobs)):
        assert db.payload == hb.payload, (
            f"device Huffman stream diverged from host reference at "
            f"sample {i}")

    with ops.count_launches() as c:
        codec.encode_batch(rows, ENTROPY_BITS)
    assert c.count == 2, (
        f"batched Huffman encode must be exactly 2 device dispatches "
        f"(histogram + pack), got {c.count}")

    reps = ENTROPY_REPEATS if quick else 2 * ENTROPY_REPEATS
    t_host, _ = _best_of(
        lambda: [codec._encode_host(r, ENTROPY_BITS) for r in rows], reps)
    t_dev, _ = _best_of(
        lambda: codec.encode_batch(rows, ENTROPY_BITS), reps)
    ratio = t_host / t_dev
    n_mb = xb.size * 4 / 1e6
    print(f"\nDevice-resident Huffman encode, B={ENTROPY_B} x "
          f"{ENTROPY_SHAPE} @ c={ENTROPY_BITS} ({n_mb:.1f} MB raw)")
    print(fmt_table(
        [["host per-tensor loop", f"{t_host * 1e3:.2f}ms", ""],
         ["device 2-dispatch batch", f"{t_dev * 1e3:.2f}ms",
          f"{ratio:.2f}x"]],
        ["path", "encode", "throughput"]))
    assert ratio >= 3.0, (
        f"device batched Huffman encode must reach >= 3x the host "
        f"per-tensor loop at B={ENTROPY_B}, got {ratio:.2f}x")
    return {
        "shape": list(ENTROPY_SHAPE), "batch": ENTROPY_B,
        "bits": ENTROPY_BITS, "host_loop_ms": t_host * 1e3,
        "device_ms": t_dev * 1e3, "throughput_x": ratio,
        "dispatches": 2,
    }


def run(quick: bool = True) -> Dict:
    shape = SHAPE_QUICK if quick else SHAPE_FULL
    x = _features(shape)
    rows = []
    results: Dict = {"shape": list(shape), "codecs": {}}
    encode_ms: Dict = {}
    for bits in BITS:
        for name in list_codecs():
            codec = get_codec(name)
            codec.encode(x, bits)            # warm up (jit compile)
            t_enc, blob = _best_of(lambda: codec.encode(x, bits))
            out = codec.decode(blob)
            out.block_until_ready()          # warm up decode
            t_dec, _ = _best_of(
                lambda: codec.decode(blob).block_until_ready()
            )
            encode_ms[(name, bits)] = t_enc * 1e3
            results["codecs"].setdefault(name, []).append({
                "bits": bits,
                "encode_ms": t_enc * 1e3,
                "decode_ms": t_dec * 1e3,
                "wire_bytes": blob.nbytes,
            })
            rows.append([
                f"c={bits}", name, f"{t_enc * 1e3:.2f}ms",
                f"{t_dec * 1e3:.2f}ms", f"{blob.nbytes:,}B",
                f"{x.size * 4 / blob.nbytes:.1f}x",
            ])
    print(f"\nBoundary codecs on {shape} float32 "
          f"({x.size * 4 / 1e6:.1f} MB raw)")
    print(fmt_table(rows, ["bits", "codec", "edge encode", "cloud decode",
                           "wire", "vs f32"]))
    for bits in BITS:
        assert encode_ms[("bitpack", bits)] < encode_ms[("huffman", bits)], (
            f"device-side bitpack encode ({encode_ms[('bitpack', bits)]:.2f}"
            f"ms) must beat host Huffman ({encode_ms[('huffman', bits)]:.2f}"
            f"ms) at c={bits}"
        )

    # ------------------------------------------------- launch accounting
    xs_micro = tuple(_features(MICRO_SHAPE, seed=10 + i)
                     for i in range(MICRO_B))
    launches = {
        "fused": _launches(
            lambda: ops.quantize_pack_impl(x, 4, interpret=True)),
        "threelaunch": _launches(
            lambda: ops.quantize_pack_threelaunch_impl(x, 4,
                                                       interpret=True)),
        "perchannel": _launches(
            lambda: ops.perchannel_encode_impl(x, 4, 1, interpret=True)),
        "batched_b8": _launches(
            lambda: ops.quantize_pack_batch_impl(jnp.stack(xs_micro), 4,
                                                 interpret=True)),
    }
    results["encode_launches"] = launches
    print("\nEdge-encode pallas_call launches: "
          + "  ".join(f"{k}={v}" for k, v in launches.items()))
    assert launches["fused"] == 1 and launches["batched_b8"] == 1
    assert launches["perchannel"] == 1
    assert launches["threelaunch"] == 3

    # ------------------------------- fused vs PR 2 three-launch encode
    # Two baselines so fusion and tile retuning are attributed separately:
    # "PR 2 as shipped" is the three-launch chain at its original
    # block_m=256, "retiled" the same chain at today's shared
    # DEFAULT_BLOCK_M — the residual fused-vs-retiled margin is the pure
    # fusion win (one dispatch, no codes round trip through HBM).
    fused_rows = []
    results["fused_vs_threelaunch"] = {}
    for bits in FUSED_BITS:
        fused = lambda: ops.quantize_pack(
            x, bits)[0].block_until_ready()                 # noqa: B023
        shipped = lambda: ops.quantize_pack_threelaunch(
            x, bits, block_m=256)[0].block_until_ready()    # noqa: B023
        retiled = lambda: ops.quantize_pack_threelaunch(
            x, bits)[0].block_until_ready()                 # noqa: B023
        fused()
        shipped()
        retiled()
        t_fused, _ = _best_of(fused)
        t_shipped, _ = _best_of(shipped)
        t_retiled, _ = _best_of(retiled)
        results["fused_vs_threelaunch"][bits] = {
            "fused_ms": t_fused * 1e3,
            "threelaunch_shipped_ms": t_shipped * 1e3,
            "threelaunch_retiled_ms": t_retiled * 1e3,
        }
        fused_rows.append([f"c={bits}", f"{t_fused * 1e3:.2f}ms",
                           f"{t_shipped * 1e3:.2f}ms",
                           f"{t_retiled * 1e3:.2f}ms",
                           f"{t_shipped / t_fused:.2f}x"])
        assert t_fused < t_shipped, (
            f"fused single-launch encode ({t_fused * 1e3:.2f}ms) must beat "
            f"the PR 2 three-launch encode ({t_shipped * 1e3:.2f}ms) at "
            f"c={bits}"
        )
    print("\nFused single-launch vs PR 2 three-launch edge encode "
          f"on {shape}")
    print(fmt_table(fused_rows, ["bits", "fused",
                                 "3-launch (PR2, bm=256)",
                                 "3-launch (retiled)", "vs PR2"]))

    # ------------------------------------ micro-batched encode throughput
    batch_rows = []
    results["batched_encode"] = {}
    for name in ("bitpack", "perchannel"):
        codec = get_codec(name)
        codec.encode(xs_micro[0], 4)
        codec.encode_batch(xs_micro, 4)       # warm up
        t_single, _ = _best_of(
            lambda: [codec.encode(xx, 4) for xx in xs_micro]
        )
        t_batch, _ = _best_of(lambda: codec.encode_batch(xs_micro, 4))
        ratio = t_single / t_batch
        results["batched_encode"][name] = {
            "shape": list(MICRO_SHAPE), "batch": MICRO_B,
            "per_tensor_ms": t_single * 1e3, "batched_ms": t_batch * 1e3,
            "throughput_x": ratio,
        }
        batch_rows.append([name, f"{t_single * 1e3:.2f}ms",
                           f"{t_batch * 1e3:.2f}ms", f"{ratio:.2f}x"])
    print(f"\nMicro-batched edge encode, B={MICRO_B} x {MICRO_SHAPE} "
          "boundaries, c=4")
    print(fmt_table(batch_rows, ["codec", f"{MICRO_B}x per-tensor",
                                 "one batched launch", "throughput"]))
    bp = results["batched_encode"]["bitpack"]["throughput_x"]
    assert bp >= 2.0, (
        f"batched bitpack encode at B={MICRO_B} must reach >= 2x the "
        f"per-tensor throughput, got {bp:.2f}x"
    )

    # -------------------------------- device-resident Huffman encode
    results["entropy_encode"] = entropy_encode_section(quick)

    return results


if __name__ == "__main__":
    import sys

    if "--entropy" in sys.argv:
        entropy_encode_section(quick="--full" not in sys.argv)
    else:
        run(quick="--full" not in sys.argv)
