"""Table II — execution speedup of JALAD vs PNG2Cloud / Origin2Cloud at
1 MBps and 300 KBps (real-world-experiment counterpart; latency from the
paper's FMAC model with its fitted constants, sizes from the measured
compression tables; Δα = 10%)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import CNN_MODELS, cnn_setup, fmt_table
from repro.config import EDGE_TX2, JaladConfig
from repro.core.decoupler import JaladEngine
from repro.core.latency import PNG_RATIO


def speedups(arch: str, bandwidth: float, quick: bool,
             edge=EDGE_TX2, acc_budget: float = 0.10):
    model, params, tables, latency_for, points = cnn_setup(arch, quick)
    lat = latency_for(edge)
    jc = JaladConfig(bits_choices=tuple(tables.bits_choices),
                     accuracy_drop_budget=acc_budget,
                     bandwidth_bytes_per_s=bandwidth, edge=edge)
    engine = JaladEngine(model, tables, lat, jc, point_indices=points)
    plan = engine.decide(bandwidth)
    jalad_t = (
        plan.predicted_latency
        if not plan.is_cloud_only
        else lat.cloud_only_time(bandwidth, PNG_RATIO)
    )
    png_t = lat.cloud_only_time(bandwidth, PNG_RATIO)
    origin_t = lat.cloud_only_time(bandwidth, 1.0)
    return png_t / jalad_t, origin_t / jalad_t, plan, jalad_t


def run(quick: bool = True) -> dict:
    out = {}
    rows = []
    for bw_name, bw in (("1MBps", 1e6), ("300KBps", 300e3)):
        for arch in CNN_MODELS:
            png_x, origin_x, plan, t = speedups(arch, bw, quick)
            out[f"{arch}@{bw_name}"] = {
                "png2cloud_x": png_x, "origin2cloud_x": origin_x,
                "point": plan.point, "bits": plan.bits,
                "jalad_latency_s": t,
            }
            rows.append([arch, bw_name, f"{png_x:.1f}x", f"{origin_x:.1f}x",
                         plan.point, plan.bits])
    print("\nTable II — speedup vs PNG2Cloud / Origin2Cloud (Δα=10%)")
    print(fmt_table(rows, ["model", "BW", "vs PNG", "vs Origin",
                           "cut", "bits"]))
    # Paper: at 300KBps JALAD achieves 3.0-7.2x vs PNG2Cloud; >1x always.
    for k, v in out.items():
        if "300KBps" in k:
            assert v["png2cloud_x"] >= 1.0, k
    best = max(v["png2cloud_x"] for k, v in out.items() if "300KBps" in k)
    assert best >= 2.0, f"expected multi-x speedup at 300KBps, best {best:.2f}"
    return out


if __name__ == "__main__":
    run()
