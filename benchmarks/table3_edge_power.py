"""Table III — impact of edge compute power (simulation, Sec. IV-E):
NVIDIA Tegra K1 (300 GFLOPs) vs Tegra X2 (2 TFLOPs) at 1 MBps.

Paper observation: the X2 gains much more ("JALAD achieves more execution
speedup gain under the high-performance edge device"); with the K1 some
networks (VGG) cannot benefit from decoupling (speedup ~1.0x vs PNG)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import CNN_MODELS, fmt_table
from repro.config import EDGE_TK1, EDGE_TX2
from benchmarks.table2_speedup import speedups


def run(quick: bool = True) -> dict:
    out = {}
    rows = []
    for arch in CNN_MODELS:
        k1_png, k1_org, k1_plan, _ = speedups(arch, 1e6, quick, edge=EDGE_TK1)
        x2_png, x2_org, x2_plan, _ = speedups(arch, 1e6, quick, edge=EDGE_TX2)
        out[arch] = {
            "tk1": {"png_x": k1_png, "origin_x": k1_org,
                    "plan": [k1_plan.point, k1_plan.bits]},
            "tx2": {"png_x": x2_png, "origin_x": x2_org,
                    "plan": [x2_plan.point, x2_plan.bits]},
        }
        rows.append([arch, f"{k1_png:.1f}x/{k1_org:.1f}x",
                     f"{x2_png:.1f}x/{x2_org:.1f}x"])
    print("\nTable III — edge power impact at 1 MB/s (PNG/Origin speedup)")
    print(fmt_table(rows, ["model", "Tegra K1", "Tegra X2"]))
    # X2 speedups dominate K1 speedups (more edge compute => deeper cuts).
    for arch in CNN_MODELS:
        assert out[arch]["tx2"]["png_x"] >= out[arch]["tk1"]["png_x"] - 1e-9
    # K1 never does worse than cloud-only (falls back to upload).
    for arch in CNN_MODELS:
        assert out[arch]["tk1"]["png_x"] >= 1.0 - 1e-9
    return out


if __name__ == "__main__":
    run()
