"""Table III revisited — per-tier energy over the three-tier path.

The original table compared Tegra K1 vs X2 *speedups*; with the
three-tier planner the edge-power story becomes a real energy benchmark:

* **Per-tier joules/request** of the chosen plan over a cellular uplink
  (1 MB/s device → edge server) + LAN backhaul (20 MB/s edge server →
  cloud): device/edge-server/cloud compute joules plus both radios
  (:meth:`TriPlanSpace.energy_of`).
* **Energy-budget-constrained plan shifts**: capping the per-request
  energy at 90% of the unconstrained plan's joules forces the planner to
  a different feasible cell — the budget mask in
  :meth:`TriPlanSpace.decide` at work.
* **Two cuts beat both two-tier plans**: on a LAN-access topology
  (device reaches an on-prem edge server over 10 MB/s Wi-Fi/LAN; the
  site's cellular/WAN uplink to the cloud is the 1 MB/s bottleneck) the
  (i1, i2) plan is compared against (a) the relay two-tier plan
  (classic JALAD cut on the device, blob relayed through the MEC site —
  the ``degenerate()`` view) and (b) hosting the whole head on the edge
  server (raw input over the LAN, then a single cut on the uplink). At
  least one (model, device) must strictly beat both: the device runs
  the cheap early layers to duck the raw-input transfer, the edge
  server carries the bulk to a late, tiny blob for the slow uplink —
  a split neither single cut can express.
"""
from __future__ import annotations

from dataclasses import replace

import numpy as np

from benchmarks.common import CNN_MODELS, fmt_table
from repro.config import (
    EDGE_SERVER_1060,
    EDGE_TK1,
    EDGE_TX2,
    TierPowerModel,
)
from repro.core.planner import _readonly
from repro.core.tri_planner import TriPlanSpace

BW1 = 1e6     # cellular uplink, the paper's headline bandwidth
BW2 = 20e6    # LAN/backhaul between the MEC site and the cloud
# LAN-access variant: fast first hop to an on-prem edge server, the
# site's cellular/WAN uplink to the cloud is the bottleneck.
LAN_BW1 = 10e6
WAN_BW2 = 1e6
ACC_BUDGET = 0.10


def tri_setup(arch: str, quick: bool, device) -> TriPlanSpace:
    """TriPlanSpace for one testbed CNN with ``device`` as the first
    tier, the 1060 MEC server in the middle, the 1080Ti cloud behind."""
    from benchmarks.common import cnn_setup

    model, params, tables, latency_for, points = cnn_setup(arch, quick)
    return TriPlanSpace.build(
        tables, latency_for(device), ACC_BUDGET,
        edge_server=EDGE_SERVER_1060, power=TierPowerModel(),
        point_indices=points,
    )


def replace_device(tri: TriPlanSpace, device) -> TriPlanSpace:
    """Re-derive the space with a different first-tier device (same
    tables, middle tier, cloud and power model)."""
    dev_vec = _readonly(device.w * tri.cum_fmacs / device.flops)
    return replace(tri, device=device, dev_vec=dev_vec,
                   mid_vec=None).finalize()


def energy_row(tri: TriPlanSpace, bw1: float, bw2: float) -> dict:
    """Per-tier energy accounting of the unconstrained plan plus the
    plan shift under a 90% energy cap."""
    plan = tri.decide(bw1, bw2)
    e_free = tri.energy_of(plan, bw1, bw2)
    t_dev, t_es, t_cl = tri.stage_times(plan)
    s1, s2 = tri.plan_sizes(plan)
    pw = tri.power
    row = {
        "plan": [plan.point, plan.bits, plan.point2, plan.bits2],
        "latency_s": plan.predicted_latency,
        "joules": e_free,
        "joules_device": pw.device_w * t_dev,
        "joules_edge_server": pw.edge_server_w * t_es,
        "joules_cloud": pw.cloud_w * t_cl,
        "joules_tx": pw.tx1_w * s1 / bw1 + pw.tx2_w * s2 / bw2,
    }
    cap = 0.9 * e_free
    capped = tri.decide(bw1, bw2, energy_budget=cap)
    e_cap = (tri.energy_of(capped, bw1, bw2)
             if not capped.is_cloud_only
             else tri.cloud_only_energy(bw1, bw2))
    row["budget_j"] = cap
    row["capped_plan"] = [capped.point, capped.bits,
                          capped.point2, capped.bits2]
    row["capped_joules"] = e_cap
    row["capped_latency_s"] = capped.predicted_latency
    row["plan_shifted"] = row["capped_plan"] != row["plan"]
    return row


def two_tier_baselines(tri: TriPlanSpace, bw1: float, bw2: float) -> dict:
    """The two plans a single cut can express on this topology."""
    # (a) classic JALAD cut on the device, blob relayed through the MEC
    # site over both links — the degenerate (diagonal) view.
    relay = tri.degenerate().decide(bw1, bw2)
    # (b) whole head on the edge server: raw input over the cellular
    # link, then a two-tier (edge-server, cloud) cut on the backhaul —
    # the ES-first degenerate view with the first link carrying the
    # uncompressed input.
    es_first = replace_device(tri, tri.edge_server).degenerate().decide(
        float("inf"), bw2)
    es_time = tri.input_bytes / bw1 + es_first.predicted_latency
    return {
        "relay_two_tier_s": relay.predicted_latency,
        "es_head_two_tier_s": es_time,
    }


def run(quick: bool = True) -> dict:
    out = {}
    rows = []
    lan_rows = []
    for arch in CNN_MODELS:
        for dev_name, dev in (("tk1", EDGE_TK1), ("tx2", EDGE_TX2)):
            tri = tri_setup(arch, quick, dev)
            row = energy_row(tri, BW1, BW2)
            out[f"{arch}@{dev_name}"] = row
            rows.append([
                arch, dev_name,
                f"{row['joules'] * 1e3:.2f}",
                f"{row['joules_device'] * 1e3:.2f}/"
                f"{row['joules_edge_server'] * 1e3:.2f}/"
                f"{row['joules_cloud'] * 1e3:.2f}",
                "yes" if row["plan_shifted"] else "no",
            ])
            # LAN-access scenario: where a second cut earns its keep.
            plan = tri.decide(LAN_BW1, WAN_BW2)
            base = two_tier_baselines(tri, LAN_BW1, WAN_BW2)
            lan = {
                "plan": [plan.point, plan.bits, plan.point2, plan.bits2],
                "latency_s": plan.predicted_latency,
                **base,
                "tri_beats_both": bool(
                    plan.predicted_latency < base["relay_two_tier_s"]
                    and plan.predicted_latency
                    < base["es_head_two_tier_s"]),
            }
            row["lan_access"] = lan
            lan_rows.append([
                arch, dev_name,
                f"{lan['latency_s'] * 1e3:.2f}",
                f"{lan['relay_two_tier_s'] * 1e3:.2f}",
                f"{lan['es_head_two_tier_s'] * 1e3:.2f}",
                "yes" if lan["tri_beats_both"] else "no",
            ])
    print("\nTable III' — per-tier energy at cellular(1MB/s)+LAN(20MB/s)")
    print(fmt_table(rows, ["model", "device", "mJ/req",
                           "dev/ES/cloud mJ", "cap shifts plan"]))
    print("\nLAN access (10MB/s) + cellular uplink (1MB/s): two cuts vs"
          " both single-cut plans")
    print(fmt_table(lan_rows, ["model", "device", "2-cut ms", "relay ms",
                               "ES-head ms", "beats both"]))
    # The 90% energy cap must be respected whenever a plan exists.
    for k, v in out.items():
        if v["capped_plan"][0] >= 0:
            assert v["capped_joules"] <= v["budget_j"] + 1e-12, k
    # The cap is 90% of the optimum's own joules, so the optimum itself
    # is excluded: the planner must land on a different cell somewhere.
    assert any(v["plan_shifted"] for v in out.values()), \
        "energy cap never shifted a plan"
    # Two ordered cuts must beat BOTH single-cut plans on at least one
    # (model, device) of the LAN-access scenario.
    assert any(v["lan_access"]["tri_beats_both"] for v in out.values()), \
        "no scenario where the two-cut plan beats both two-tier plans"
    return out


if __name__ == "__main__":
    run()
