"""Calibration pipeline benchmark: the vectorized one-pass ``build_tables``
vs the historical per-cell loop (``build_tables_reference``), plus the
config-hashed table cache that lets server startup skip recalibration.

Claims checked by assertion (so ``benchmarks.run`` fails loudly if they
regress):

1. **>= 3x end-to-end table-build speedup** at paper scale on the CNN
   testbed — N >= 16 decoupling points, C >= 4 bit widths, K >= 2 codecs
   — with warm jit caches (the steady-state recalibration cost an
   adaptive deployment actually pays).
2. **Bitwise-equal tables**: acc_drop, size_bytes and base_accuracy of
   the two paths are identical to the last bit.
3. **Device/host traffic**: the vectorized path issues ONE jitted step
   dispatch and ONE host sync per calibration batch; the reference pays
   one tail launch + sync per (point, bits, value-transform) cell.
4. **Cache-hit startup**: ``build_edge_cloud_server`` with a
   ``tables_cache_dir`` loads the persisted tables on a repeat config
   and starts faster than the cold calibrating build.
"""
from __future__ import annotations

import dataclasses
import tempfile
import time
from typing import Dict

import jax
import numpy as np

from benchmarks.common import fmt_table
from repro.config import JaladConfig, get_config
from repro.core import predictor as pred
from repro.core.predictor import build_tables, build_tables_reference
from repro.data.synthetic import make_batch
from repro.models.api import build_model
from repro.serving.edge_cloud import build_edge_cloud_server

BITS_QUICK = (2, 3, 4, 8)
BITS_FULL = (2, 3, 4, 6, 8)
CODECS_QUICK = ("huffman", "bitpack")
CODECS_FULL = ("huffman", "bitpack", "perchannel")


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return time.perf_counter() - t0, out


def run(quick: bool = True) -> Dict:
    # resnet50 reduced: 32x32, 20 cut points (res-unit granularity) —
    # the paper's own testbed, and the geometry where per-cell dispatch
    # overhead (what the vectorized pipeline removes) is representative.
    cfg = get_config("resnet50").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    bits = BITS_QUICK if quick else BITS_FULL
    codecs = CODECS_QUICK if quick else CODECS_FULL
    n_batches, bsz = (1, 4) if quick else (2, 8)
    batches = [make_batch(cfg, bsz, 0, seed=10 + i)
               for i in range(n_batches)]
    n_points = len(model.decoupling_points())
    # Paper scale, per the acceptance bar of the vectorized pipeline.
    assert n_points >= 16 and len(bits) >= 4 and len(codecs) >= 2

    def ref():
        return build_tables_reference(model, params, batches, list(bits),
                                      codecs=codecs)

    def vec():
        return build_tables(model, params, batches, list(bits),
                            codecs=codecs)

    t_ref_cold, tab_ref = _timed(ref)
    ref_stats = dataclasses.replace(pred.LAST_BUILD_STATS)
    t_vec_cold, tab_vec = _timed(vec)
    vec_stats = dataclasses.replace(pred.LAST_BUILD_STATS)

    # Bitwise equality of the full tables.
    assert tab_ref.points == tab_vec.points
    np.testing.assert_array_equal(tab_ref.acc_drop, tab_vec.acc_drop)
    np.testing.assert_array_equal(tab_ref.size_bytes, tab_vec.size_bytes)
    assert tab_ref.base_accuracy == tab_vec.base_accuracy

    # Warm rebuilds: jit caches populated, the recurring recalibration
    # cost (the vectorized step fns are cached on the model instance).
    t_ref_warm, _ = _timed(ref)
    t_vec_warm, _ = _timed(vec)
    speedup_warm = t_ref_warm / t_vec_warm
    speedup_cold = t_ref_cold / t_vec_cold

    rows = [
        ["reference loop", f"{t_ref_cold:.1f}s", f"{t_ref_warm:.1f}s",
         ref_stats.step_dispatches, ref_stats.host_syncs,
         ref_stats.size_calls],
        ["vectorized one-pass", f"{t_vec_cold:.1f}s", f"{t_vec_warm:.1f}s",
         vec_stats.step_dispatches, vec_stats.host_syncs,
         vec_stats.size_calls],
    ]
    print(f"\nCalibration table build on {cfg.arch_id} "
          f"(N={n_points} points, C={len(bits)} bits, K={len(codecs)} "
          f"codecs, {n_batches}x{bsz} samples)")
    print(fmt_table(rows, ["path", "cold", "warm (jit cached)",
                           "dispatches", "host syncs", "size calls"]))
    print(f"table-build speedup: {speedup_warm:.1f}x warm, "
          f"{speedup_cold:.1f}x cold; tables bitwise-equal")

    assert speedup_warm >= 3.0, (
        f"vectorized calibration must be >= 3x the reference loop "
        f"(warm), got {speedup_warm:.2f}x "
        f"({t_ref_warm:.1f}s vs {t_vec_warm:.1f}s)"
    )
    # Traffic accounting: one dispatch + one sync per batch, vs one tail
    # sync per (point, bits, value-transform) cell in the loop.
    assert vec_stats.step_dispatches == n_batches
    assert vec_stats.host_syncs == n_batches
    assert ref_stats.host_syncs > n_points * len(bits) * n_batches

    # ----------------------------------------- cache-hit server startup
    jc = JaladConfig(bits_choices=(2, 4, 8), accuracy_drop_budget=0.10,
                     codec_choices=("bitpack", "huffman"))
    srv_points = [2, 6, 10, 14]
    with tempfile.TemporaryDirectory() as cache_dir:
        def start():
            return build_edge_cloud_server(
                cfg, jc, calib_batches=1, calib_batch_size=2,
                points=srv_points, tables_cache_dir=cache_dir,
            )

        t_cold_start, (srv_a, _) = _timed(start)
        t_hit_start, (srv_b, _) = _timed(start)
        np.testing.assert_array_equal(srv_a.engine.tables.size_bytes,
                                      srv_b.engine.tables.size_bytes)
        np.testing.assert_array_equal(srv_a.engine.tables.acc_drop,
                                      srv_b.engine.tables.acc_drop)
    print(f"server startup: {t_cold_start:.1f}s calibrating cold vs "
          f"{t_hit_start:.1f}s on a table-cache hit "
          f"({t_cold_start / t_hit_start:.1f}x)")
    assert t_hit_start < t_cold_start, (
        "cache-hit startup must beat the cold calibrating build"
    )

    results = {
        "arch": cfg.arch_id,
        "n_points": n_points,
        "bits": list(bits),
        "codecs": list(codecs),
        "calib": {"batches": n_batches, "batch_size": bsz},
        "reference": {
            "cold_s": t_ref_cold, "warm_s": t_ref_warm,
            "dispatches": ref_stats.step_dispatches,
            "host_syncs": ref_stats.host_syncs,
            "size_calls": ref_stats.size_calls,
        },
        "vectorized": {
            "cold_s": t_vec_cold, "warm_s": t_vec_warm,
            "dispatches": vec_stats.step_dispatches,
            "host_syncs": vec_stats.host_syncs,
            "size_calls": vec_stats.size_calls,
        },
        "speedup_warm_x": speedup_warm,
        "speedup_cold_x": speedup_cold,
        "tables_bitwise_equal": True,
        "startup": {
            "cold_s": t_cold_start,
            "cache_hit_s": t_hit_start,
            "speedup_x": t_cold_start / t_hit_start,
        },
    }
    return results
