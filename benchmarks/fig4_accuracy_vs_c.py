"""Fig. 4 — accuracy loss A(c) versus quantization bits c.

The paper's claim: "c >= 4 already provides certain accuracy loss
guarantee of 10%". ILSVRC2012 is unavailable offline, so we TRAIN a small
CNN on the synthetic separable image task to high accuracy, then measure
true accuracy drop under boundary quantization at each c.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import fmt_table
from repro.config import TrainConfig, get_config
from repro.core.predictor import build_tables
from repro.data.synthetic import ImageStream
from repro.models.api import build_model
from repro.training.loop import train


def _trained_cnn(quick: bool, seed: int = 0):
    cfg = get_config("resnet50").reduced()
    model = build_model(cfg)
    stream = ImageStream(cfg.num_classes, batch=32,
                         image_size=cfg.image_size, seed=seed)

    def batches():
        for b in stream:
            yield b

    steps = 60 if quick else 300
    tc = TrainConfig(learning_rate=3e-3, total_steps=steps,
                     warmup_steps=10, log_every=0)
    res = train(model, tc, batches(), num_steps=steps)
    return model, res.params


def run(quick: bool = True) -> dict:
    model, params = _trained_cnn(quick)
    cfg = model.cfg
    stream = ImageStream(cfg.num_classes, batch=64,
                         image_size=cfg.image_size, seed=123)
    eval_batches = [next(iter(stream)) for _ in range(1 if quick else 4)]
    bits = [2, 3, 4, 5, 6, 8]
    n = len(model.decoupling_points())
    tables = build_tables(model, params, eval_batches, bits,
                          points=[n // 2])
    drops = tables.drops()[0]
    out = {
        "base_accuracy": tables.base_accuracy,
        "bits": bits,
        "acc_drop": drops.tolist(),
    }
    rows = [[f"c={b}", f"{d:.3f}"] for b, d in zip(bits, drops)]
    print("\nFig. 4 — accuracy drop vs quantization bits "
          f"(trained CNN, base acc {tables.base_accuracy:.3f})")
    print(fmt_table(rows, ["bits", "accuracy drop"]))
    # Paper claim: c >= 4 keeps the drop within 10%.
    for b, d in zip(bits, drops):
        if b >= 4:
            assert d <= 0.10, f"c={b} drop {d:.3f} > 10%"
    # And the curve is (weakly) improving with bits.
    assert drops[0] >= drops[-1] - 1e-6
    return out


if __name__ == "__main__":
    run()
