"""Fig. 5 — predictor stability: A_i(c) and S_i(c) measured on different
data epochs overlap, so a one-shot lookup table is sound."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import fmt_table
from repro.config import get_config
from repro.core.predictor import build_tables
from repro.data.synthetic import make_batch
from repro.models.api import build_model


def run(quick: bool = True) -> dict:
    cfg = get_config("resnet50").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    bits = [8]
    epochs = 2 if quick else 5
    bsz = 16 if quick else 64
    tabs = []
    for e in range(epochs):
        batches = [make_batch(cfg, bsz, 0, seed=1000 * e + i)
                   for i in range(1 if quick else 3)]
        tabs.append(build_tables(model, params, batches, bits))
    sizes = np.stack([t.sizes()[:, 0] for t in tabs])   # (E, N)
    accs = np.stack([t.drops()[:, 0] for t in tabs])
    size_rel_spread = (sizes.max(0) - sizes.min(0)) / sizes.mean(0)
    acc_spread = accs.max(0) - accs.min(0)
    out = {
        "epochs": epochs,
        "size_rel_spread_median": float(np.median(size_rel_spread)),
        "size_rel_spread_max": float(size_rel_spread.max()),
        "acc_spread_median": float(np.median(acc_spread)),
        "acc_spread_max": float(acc_spread.max()),
    }
    print("\nFig. 5 — predictor stability across epochs (c=8)")
    print(fmt_table(
        [[f"{out['size_rel_spread_median']:.3f}",
          f"{out['size_rel_spread_max']:.3f}",
          f"{out['acc_spread_median']:.3f}",
          f"{out['acc_spread_max']:.3f}"]],
        ["size spread (med)", "size spread (max)",
         "acc spread (med)", "acc spread (max)"],
    ))
    # Paper: "results of different epochs are highly overlapped".
    assert out["size_rel_spread_median"] < 0.1
    return out


if __name__ == "__main__":
    run()
