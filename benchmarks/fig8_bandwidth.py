"""Fig. 8 — execution latency under varying edge-cloud bandwidth: JALAD
stays low & stable by re-deciding the cut; the cloud-only baselines degrade
~1/BW. At good bandwidth JALAD converges to PNG2Cloud (same plan)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import cnn_setup, fmt_table
from repro.config import EDGE_TX2, JaladConfig
from repro.core.decoupler import JaladEngine
from repro.core.latency import PNG_RATIO


def run(quick: bool = True) -> dict:
    arch = "resnet50"
    model, params, tables, latency_for, points = cnn_setup(arch, quick)
    lat = latency_for(EDGE_TX2)
    bws = [50e3, 100e3, 300e3, 600e3, 1e6, 1.5e6]
    out = {"arch": arch, "bandwidths": bws, "jalad": [], "png": [],
           "origin": [], "plans": []}
    rows = []
    for bw in bws:
        jc = JaladConfig(bits_choices=tuple(tables.bits_choices),
                         accuracy_drop_budget=0.10,
                         bandwidth_bytes_per_s=bw)
        engine = JaladEngine(model, tables, lat, jc, point_indices=points)
        plan = engine.decide(bw)
        jalad_t = (plan.predicted_latency if not plan.is_cloud_only
                   else lat.cloud_only_time(bw, PNG_RATIO))
        png_t = lat.cloud_only_time(bw, PNG_RATIO)
        origin_t = lat.cloud_only_time(bw, 1.0)
        jalad_t = min(jalad_t, png_t)    # JALAD may pick the upload plan
        out["jalad"].append(jalad_t)
        out["png"].append(png_t)
        out["origin"].append(origin_t)
        out["plans"].append([plan.point, plan.bits])
        rows.append([f"{bw/1e3:.0f}KB/s", f"{jalad_t*1e3:.1f}ms",
                     f"{png_t*1e3:.1f}ms", f"{origin_t*1e3:.1f}ms",
                     plan.point, plan.bits])
    print("\nFig. 8 — latency vs bandwidth (Δα=10%)")
    print(fmt_table(rows, ["BW", "JALAD", "PNG2Cloud", "Origin2Cloud",
                           "cut", "bits"]))
    # Stability: across a 30x bandwidth range, JALAD's latency varies far
    # less than the baselines'.
    j = np.array(out["jalad"]);  p = np.array(out["png"])
    assert j.max() / j.min() < 0.7 * (p.max() / p.min())
    # JALAD never loses to the baselines.
    assert (j <= p + 1e-9).all()
    return out


if __name__ == "__main__":
    run()
