"""Pipelined vs synchronous edge-cloud serving, plus fused-codec validation.

Two claims, checked by assertion (so ``benchmarks.run`` fails loudly if
either regresses):

1. The 3-stage pipeline (``repro.serving.pipeline``) finishes a request
   stream in less simulated wall-clock than back-to-back serving for
   every benchmarked (model, bandwidth) config. Both paths execute the
   real decoupled numerics; the clock uses the paper's FMAC model.

2. The fused Pallas dequant kernels (single ``pallas_call`` cloud codec)
   match the pure-jnp oracle in ``kernels/quantize/ref.py`` bit-exactly
   under ``interpret=True``. The oracle is jit-compiled, exactly as the
   serving path runs it — an *eager* oracle dispatches mul and add as two
   XLA:CPU kernels and so misses the fused multiply-add rounding, which
   is a dispatch artifact, not kernel math.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import fmt_table
from repro.config import JaladConfig, get_config
from repro.data.synthetic import make_batch
from repro.kernels.quantize import ops
from repro.kernels.quantize import ref as kref
from repro.serving.edge_cloud import build_edge_cloud_server
from repro.serving.pipeline import PipelinedEdgeCloudServer, PipelineRequest

CONFIGS = [
    # (arch, bandwidth B/s): one transfer-bound, one more compute-bound
    ("resnet50", 300e3),
    ("vgg16", 1e6),
]


def _fused_codec_bitexact(quick: bool) -> Dict:
    shapes = [(256, 128), (3, 5, 7), (300,)] if quick else [
        (256, 128), (3, 5, 7), (300,), (64, 64, 8), (1024, 128), (129,),
    ]
    rows = []
    for shape in shapes:
        for bits in (2, 4, 8):
            rng = np.random.default_rng(hash((shape, bits)) % 2**31)
            x = rng.standard_normal(shape).astype(np.float32)
            x[np.abs(x) < 0.3] = 0.0
            xj = jnp.asarray(x)
            # codes path (the wire format): kernel vs oracle, bit-exact
            codes, mn, mx = ops.quantize_pack(xj, bits, interpret=True)
            got = ops.dequantize_unpack(codes, mn, mx, bits, shape,
                                        interpret=True)
            want_codes, wmn, wmx = kref.quantize_ref(xj, bits)
            want = jax.jit(
                lambda c, lo, hi: kref.dequantize_ref(c, lo, hi, bits)
            )(want_codes, wmn, wmx)
            exact = bool(np.array_equal(np.asarray(got, np.float32),
                                        np.asarray(want, np.float32)))
            # cloud codec entry point (uint8 codes from the Huffman
            # decoder) through the fused dequant+cast kernel
            got2 = ops.dequantize_codes(
                jnp.asarray(want_codes, jnp.uint8), wmn, wmx, bits, shape,
                interpret=True,
            )
            exact2 = bool(np.array_equal(np.asarray(got2, np.float32),
                                         np.asarray(want, np.float32)))
            assert exact and exact2, (shape, bits, exact, exact2)
            rows.append([str(shape), bits, "bit-exact"])
    print(fmt_table(rows, ["shape", "bits", "fused dequant vs ref.py"]))
    return {"cases": len(rows), "bitexact": True}


def _pipeline_speedup(arch: str, bandwidth: float, quick: bool) -> Dict:
    cfg = get_config(arch).reduced() if quick else get_config(arch)
    jc = JaladConfig(bits_choices=(2, 4, 8), accuracy_drop_budget=0.10,
                     bandwidth_bytes_per_s=bandwidth)
    srv, params = build_edge_cloud_server(
        cfg, jc, calib_batches=1 if quick else 4,
        calib_batch_size=4 if quick else 16,
    )
    n_req = 8 if quick else 64
    bsz = 2 if quick else 16
    batches = [make_batch(cfg, bsz, 0, seed=100 + i) for i in range(n_req)]

    pipe = PipelinedEdgeCloudServer(srv.engine, params)
    pipe.controller.observe_transfer(bandwidth, 1.0)   # warm estimate
    done = pipe.serve([
        PipelineRequest(uid=i, batch=b, bandwidth=bandwidth)
        for i, b in enumerate(batches)
    ])
    pipelined = pipe.makespan_s
    synchronous = pipe.synchronous_time_s()
    speedup = synchronous / max(pipelined, 1e-12)
    assert pipelined < synchronous, (
        f"{arch}@{bandwidth:.0f}B/s: pipeline {pipelined:.6f}s did not beat "
        f"synchronous {synchronous:.6f}s"
    )
    return {
        "arch": arch,
        "bandwidth_Bps": bandwidth,
        "requests": n_req,
        "pipelined_s": pipelined,
        "synchronous_s": synchronous,
        "speedup": speedup,
        "plans": sorted({(r.timeline.plan_point, r.timeline.plan_bits)
                         for r in done}),
    }


def run(quick: bool = True) -> Dict:
    codec = _fused_codec_bitexact(quick)
    rows = []
    configs = []
    for arch, bw in CONFIGS:
        r = _pipeline_speedup(arch, bw, quick)
        configs.append(r)
        rows.append([arch, f"{bw / 1e3:.0f}KB/s", r["requests"],
                     f"{r['synchronous_s'] * 1e3:.2f}ms",
                     f"{r['pipelined_s'] * 1e3:.2f}ms",
                     f"{r['speedup']:.2f}x"])
    print(fmt_table(rows, ["model", "bandwidth", "reqs", "synchronous",
                           "pipelined", "speedup"]))
    payload = {"fused_codec": codec, "configs": configs}
    return payload
