"""Roofline table (deliverable g): per (arch x shape), the three roofline
terms on the single-pod 16x16 v5e mesh.

Primary source: the dry-run JSONL records under results/ (produced by
``python -m repro.launch.dryrun --all --out results/dryrun_1pod.jsonl``,
which lowers + compiles every combination and parses the compiled HLO).
When a combo has no record yet, an analytic-only row (compute & HBM terms
from the model's own accounting, collective term marked n/a) is shown so
the table is always complete.
"""
from __future__ import annotations

import json
import os
from typing import Dict, Optional

import numpy as np

from benchmarks.common import RESULTS_DIR, fmt_table
from repro.config import (
    INPUT_SHAPES,
    TPU_V5E,
    TPU_V5E_HBM_BW,
    get_config,
)
from repro.config.registry import assigned_archs
from repro.models.api import build_model

DRYRUN_FILES = ["dryrun_1pod.jsonl"]
CHIPS = 256


def load_dryrun_records() -> Dict:
    recs = {}
    for fname in DRYRUN_FILES:
        path = os.path.join(RESULTS_DIR, fname)
        if not os.path.exists(path):
            continue
        with open(path) as f:
            for line in f:
                r = json.loads(line)
                recs[(r["arch"], r["shape"])] = r
    return recs


def analytic_row(arch: str, shape_name: str) -> Dict:
    """Compute/memory terms without a compiled artifact (no collectives)."""
    model = build_model(get_config(arch))
    shape = INPUT_SHAPES[shape_name]
    flops = model.analytic_step_flops(
        shape, block_remat=(shape.mode == "train"))
    # HBM traffic lower bound: params read once + activations/caches.
    nbytes = 2.0 * model.param_count()
    if shape.mode == "decode":
        cache = model.input_specs(shape)["caches"]
        import jax
        nbytes += sum(
            np.prod(l.shape) * l.dtype.itemsize
            for l in jax.tree.leaves(cache)
        )
    compute_s = flops / CHIPS / TPU_V5E.flops
    memory_s = nbytes / CHIPS / TPU_V5E_HBM_BW
    return {
        "arch": arch, "shape": shape_name, "mesh": "16x16", "chips": CHIPS,
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": float("nan"),
        "dominant": "compute" if compute_s > memory_s else "memory",
        "model_flops_global": model.model_flops(
            shape.global_batch * (shape.seq_len
                                  if shape.mode != "decode" else 1)),
        "useful_flops_fraction": float("nan"),
        "hbm_gib_per_device": nbytes / CHIPS / 2**30,
        "source": "analytic",
    }


def run(quick: bool = True) -> dict:
    recs = load_dryrun_records()
    rows = []
    out = {}
    for arch in assigned_archs():
        for shape_name in INPUT_SHAPES:
            r = recs.get((arch, shape_name))
            if r is None:
                r = analytic_row(arch, shape_name)
            src = r.get("source", "dryrun")
            out[f"{arch}|{shape_name}"] = r
            coll = r.get("collective_s", float("nan"))
            rows.append([
                arch, shape_name,
                f"{r['compute_s']*1e3:9.2f}",
                f"{r['memory_s']*1e3:9.2f}",
                f"{coll*1e3:9.2f}" if coll == coll else "      n/a",
                r["dominant"],
                f"{r.get('useful_flops_fraction', float('nan')):.2f}",
                src,
            ])
    print("\nRoofline terms per (arch x shape), 16x16 v5e pod "
          "(ms per step, per device)")
    print(fmt_table(rows, ["arch", "shape", "compute", "memory",
                           "collective", "dominant", "useful", "src"]))
    n_dryrun = sum(1 for v in out.values() if v.get("source") != "analytic")
    print(f"\n{n_dryrun}/40 rows from compiled dry-run artifacts, "
          f"{40 - n_dryrun} analytic-only")
    return out


if __name__ == "__main__":
    run()
