"""Token-streaming decoupled serving: the amortized per-token wire.

The tentpole perf claim of the token-level serving path
(``repro.serving.streaming``): with 8 slots generating concurrently,
encoding every slot's ``(1, 1, d_model)`` boundary row per engine step
as ONE batched fused launch must beat the per-slot encode loop by >= 2x
— per-token fixed costs (kernel dispatch, host framing) dominate at
this tensor size, and the batch amortizes them. The gate is asserted
two ways: launch accounting (1 batched dispatch vs 8) and wall clock.

Also reported (not gated): steady-state tokens/s of a real
:class:`TokenStreamSession` on an LM config, against the planner's
modeled cloud-only generation loop at the same bandwidth
(``StreamPlanTerms.token_time`` vs ``cloud_only_stream_time`` terms),
plus the serving-time int8 KV-cache byte ratio of the cloud tail, and
the same session forced onto a huffman-codec plan — asserting the
per-step boundary group encodes in exactly 2 device dispatches
(the device-resident histogram + pack path) and reporting its
ms/token.
"""
from __future__ import annotations

import time
from dataclasses import replace
from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from benchmarks.common import fmt_table
from repro.codec import get_codec
from repro.config import JaladConfig, get_config
from repro.config.types import ServeConfig
from repro.kernels.quantize import ops
from repro.serving.scheduler import GenRequest
from repro.serving.streaming import TokenStreamSession

SLOTS = 8
BITS = 8
BANDWIDTH = 1e5                 # bytes/s — the regime where the cut pays
EXPECTED_TOKENS = 64.0
REPEATS = 5


def _rows(d_model: int, seed: int = 0) -> List[jnp.ndarray]:
    rng = np.random.default_rng(seed)
    return [jnp.asarray(rng.standard_normal((1, 1, d_model)),
                        jnp.float32) for _ in range(SLOTS)]


def _best_of(fn, repeats: int = REPEATS) -> float:
    best = np.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _encode_gate(d_model: int) -> Dict:
    """Launches + wall clock: batched 8-slot boundary encode vs the
    per-slot loop, on the eager impls (under jit the dispatch happens
    once at trace time, so the impls are what launch accounting and
    dispatch-overhead timing must measure — same methodology as
    ``benchmarks/codec.py``)."""
    rows = _rows(d_model)
    stacked = jnp.stack(rows)

    def per_slot():
        for r in rows:
            ops.quantize_pack_impl(r, BITS)[0].block_until_ready()

    def batched():
        ops.quantize_pack_batch_impl(stacked, BITS)[0].block_until_ready()

    per_slot()                   # warm up
    batched()
    with ops.count_launches() as c:
        per_slot()
    per_slot_launches = c.count
    with ops.count_launches() as c:
        batched()
    batched_launches = c.count
    t_loop = _best_of(per_slot)
    t_batch = _best_of(batched)
    speedup = t_loop / t_batch

    # The codec-level path the engine actually calls (framing included).
    codec = get_codec("bitpack")
    codec.encode_batch(rows, BITS)
    t_codec_loop = _best_of(lambda: [codec.encode(r, BITS) for r in rows])
    t_codec_batch = _best_of(lambda: codec.encode_batch(rows, BITS))

    out = {
        "slots": SLOTS,
        "bits": BITS,
        "d_model": d_model,
        "per_slot_launches": per_slot_launches,
        "batched_launches": batched_launches,
        "per_slot_ms": t_loop * 1e3,
        "batched_ms": t_batch * 1e3,
        "speedup_x": speedup,
        "codec_per_slot_ms": t_codec_loop * 1e3,
        "codec_batched_ms": t_codec_batch * 1e3,
        "codec_speedup_x": t_codec_loop / t_codec_batch,
    }
    assert batched_launches == 1, (
        f"batched 8-slot encode must be ONE launch, got {batched_launches}")
    assert per_slot_launches == SLOTS
    assert speedup >= 2.0, (
        f"batched per-token encode {speedup:.2f}x over per-slot loop — "
        "the >=2x amortization gate failed")
    return out


def _stream_report(quick: bool) -> Dict:
    """Steady-state tokens/s of a real streaming session on an LM config,
    vs the planner's modeled cloud-only generation loop."""
    import jax

    from repro.serving.edge_cloud import build_edge_cloud_server

    cfg = get_config("olmo-1b").reduced()
    jcfg = JaladConfig(bandwidth_bytes_per_s=BANDWIDTH,
                       bits_choices=(2, 4, 8),
                       codec_choices=("bitpack", "huffman"))
    srv, params = build_edge_cloud_server(
        cfg, jcfg, calib_batches=1, calib_batch_size=2, seq_len=16)
    engine = srv.engine
    plan = engine.decide_streaming(BANDWIDTH, EXPECTED_TOKENS)
    terms = engine.stream_terms
    tok_t = terms.token_time(plan, BANDWIDTH)
    cloud_tok_t = terms.token_time(
        terms.cloud_only_plan(BANDWIDTH, EXPECTED_TOKENS), BANDWIDTH)

    sess = TokenStreamSession(engine.model, params,
                              ServeConfig(max_batch=SLOTS, max_seq_len=32),
                              plan=plan)
    rng = np.random.default_rng(0)
    n_tok = 8 if quick else 24
    for i in range(SLOTS):
        sess.submit(GenRequest(
            uid=i, tokens=rng.integers(1, 100, size=4).astype(np.int32),
            max_new_tokens=n_tok))
    sess.step()                  # warm up compiles (prefill + first step)
    t0 = time.perf_counter()
    sess.run()
    wall = time.perf_counter() - t0
    measured = (sess.tokens_out - SLOTS) / max(wall, 1e-9)

    # Same cut forced onto the huffman wire: the per-step boundary
    # group must ride the two-dispatch device-resident entropy encode
    # (histogram + pack), never a per-slot host loop. Launch accounting
    # is asserted around the encode itself so the tail decode's own
    # dispatch cannot mask a regression.
    hplan = replace(plan, codec="huffman")
    hsess = TokenStreamSession(engine.model, params,
                               ServeConfig(max_batch=SLOTS,
                                           max_seq_len=32),
                               plan=hplan)
    for i in range(SLOTS):
        hsess.submit(GenRequest(
            uid=i, tokens=rng.integers(1, 100, size=4).astype(np.int32),
            max_new_tokens=n_tok))
    hsess.step()                 # prefill + compile
    hsess.step()                 # steady state
    enc_counts: List[int] = []
    orig_encode = hsess._codec.encode_batch

    def _counted(xs, bits):
        with ops.count_launches() as c:
            out = orig_encode(xs, bits)
        enc_counts.append(c.count)
        return out

    hsess._codec.encode_batch = _counted
    try:
        hsess.step()
    finally:
        del hsess._codec.encode_batch
    assert enc_counts == [2], (
        f"huffman-plan step must encode its boundary group in exactly "
        f"2 device dispatches (histogram + pack), saw {enc_counts}")
    n_timed = 4 if quick else 12
    t0 = time.perf_counter()
    for _ in range(n_timed):
        hsess.step()
    hwall = time.perf_counter() - t0
    huffman_ms_per_token = hwall / (n_timed * SLOTS) * 1e3

    del jax
    return {
        "point": plan.point,
        "bits": plan.bits,
        "codec": plan.codec,
        "bandwidth_Bps": BANDWIDTH,
        "token_time_model_s": tok_t,
        "cloud_only_token_s": cloud_tok_t,
        "cloud_only_vs_plan_x": cloud_tok_t / tok_t,
        "measured_tokens_per_s": measured,
        "tokens_generated": sess.tokens_out,
        "wire_bytes_per_token": (sess.bytes_sent - sess.header.nbytes)
        / max(sess.tokens_out, 1),
        "kv_bytes_ratio": (sess.kv_bytes_ratio
                           if sess.kv_bytes_ratio is not None else 1.0),
        "huffman_ms_per_token": huffman_ms_per_token,
        "huffman_encode_launches_per_step": enc_counts[0],
    }


def run(quick: bool = True) -> Dict:
    cfg = get_config("olmo-1b")
    gate = _encode_gate(int(cfg.d_model))
    stream = _stream_report(quick)
    print(f"\nToken streaming — batched per-token encode, "
          f"{SLOTS} slots x (1, 1, {gate['d_model']}) @ c={BITS}")
    print(fmt_table(
        [["per-slot loop", str(gate["per_slot_launches"]),
          f"{gate['per_slot_ms']:.2f}ms", ""],
         ["batched", str(gate["batched_launches"]),
          f"{gate['batched_ms']:.2f}ms", f"{gate['speedup_x']:.1f}x"]],
        ["path", "launches", "time", "speedup"]))
    print(f"\nSteady state @ {BANDWIDTH:.0f} B/s: plan "
          f"(i={stream['point']}, c={stream['bits']}, "
          f"{stream['codec']}) modeled at "
          f"{stream['token_time_model_s'] * 1e3:.2f}ms/tok "
          f"(cloud-only generation loop: "
          f"{stream['cloud_only_token_s'] * 1e3:.2f}ms/tok — the decoupled "
          f"wire carries the boundary row, not a 4-byte id); measured "
          f"{stream['measured_tokens_per_s']:.1f} tok/s, int8 tail KV at "
          f"{stream['kv_bytes_ratio']:.2f}x fp bytes")
    print(f"Huffman-plan wire: "
          f"{stream['huffman_ms_per_token']:.2f}ms/token with the "
          f"boundary group encoded in "
          f"{stream['huffman_encode_launches_per_step']} device "
          f"dispatches per step (histogram + pack)")
    return {"encode_gate": gate, "stream": stream}


if __name__ == "__main__":
    import sys

    run(quick="--full" not in sys.argv)
