"""Sec. III-E — ILP solve time. The paper reports 1.77 ms for an N*C-size
problem on an i7-6800K. We time both solvers at paper scale and larger."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import fmt_table
from repro.core.ilp import ILPProblem, solve_branch_and_bound, solve_enumeration


def _time(fn, p, reps=50):
    fn(p)  # warmup
    t0 = time.perf_counter()
    for _ in range(reps):
        fn(p)
    return (time.perf_counter() - t0) / reps * 1e3


def run(quick: bool = True) -> dict:
    rng = np.random.default_rng(0)
    out = {}
    rows = []
    for (n, c) in [(20, 7), (50, 16), (200, 16), (1000, 16)]:
        p = ILPProblem(rng.random((n, c)) * 10, rng.random((n, c)) * 0.3,
                       0.15)
        te = _time(solve_enumeration, p)
        tb = _time(solve_branch_and_bound, p)
        out[f"{n}x{c}"] = {"enumeration_ms": te, "bnb_ms": tb}
        rows.append([f"{n}x{c}", f"{te:.3f}ms", f"{tb:.3f}ms"])
    print("\nILP solve time (paper: 1.77 ms at ~N*C scale)")
    print(fmt_table(rows, ["N x C", "enumeration", "branch&bound"]))
    assert out["50x16"]["enumeration_ms"] < 10.0
    return out


if __name__ == "__main__":
    run()
