"""Benchmark driver: one module per paper table/figure + the roofline.

  PYTHONPATH=src python -m benchmarks.run            # quick (CPU-minutes)
  PYTHONPATH=src python -m benchmarks.run --full     # paper-scale
  PYTHONPATH=src python -m benchmarks.run --only table2_speedup
"""
from __future__ import annotations

import argparse
import importlib
import sys
import time
import traceback

MODULES = [
    "fig2_amplification",
    "fig3_compression",
    "fig4_accuracy_vs_c",
    "fig5_stability",
    "fig6_per_layer",
    "table2_speedup",
    "fig7_threshold",
    "fig8_bandwidth",
    "table3_edge_power",
    "ilp_solve_time",
    "calibration",
    "codec",
    "fleet",
    "pipeline_serving",
    "token_streaming",
    "meshed_tail",
    "roofline",
]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args(argv)
    mods = [args.only] if args.only else MODULES
    quick = not args.full
    failures = []
    t00 = time.perf_counter()
    for name in mods:
        t0 = time.perf_counter()
        print(f"\n{'=' * 72}\n== benchmarks.{name}\n{'=' * 72}")
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            payload = mod.run(quick=quick)
            if isinstance(payload, dict):
                # Machine-readable trajectory: every scalar in the payload
                # appends to results/BENCH_<name>.json (see record_bench).
                from benchmarks.common import flatten_metrics, record_bench

                metrics = flatten_metrics(payload)
                if metrics:
                    record_bench(name, metrics, quick=quick)
            print(f"-- {name} OK ({time.perf_counter() - t0:.1f}s)")
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            failures.append((name, repr(e)))
    print(f"\n{'=' * 72}")
    print(f"{len(mods) - len(failures)}/{len(mods)} benchmarks passed "
          f"in {time.perf_counter() - t00:.0f}s")
    for n, e in failures:
        print(f"  FAIL {n}: {e}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
