"""End-to-end training driver: train a ~100M-param dense LM for a few
hundred steps on the synthetic token stream and watch the loss drop.

  PYTHONPATH=src python examples/train_lm.py                 # ~100M, 200 steps
  PYTHONPATH=src python examples/train_lm.py --tiny          # CPU-quick smoke

The model is the olmo-1b family scaled to ~100M params (8 layers x 768).
Uses the same TrainConfig / train loop / AdamW / checkpointing stack the
launcher uses.
"""
import argparse

from repro.config import TrainConfig, get_config
from repro.data.synthetic import ShardedLoader
from repro.models.api import build_model
from repro.training.loop import train

ap = argparse.ArgumentParser()
ap.add_argument("--tiny", action="store_true", help="CPU-quick smoke sizes")
ap.add_argument("--steps", type=int, default=None)
args = ap.parse_args()

base = get_config("olmo-1b")
if args.tiny:
    cfg = base.reduced()
    steps = args.steps or 30
    batch, seq = 8, 64
else:
    # ~100M params: 8 x d768 with the olmo flavour (non-parametric LN, tied)
    cfg = base.replace(num_layers=8, d_model=768, num_heads=12,
                       num_kv_heads=12, d_ff=3072, vocab_size=50304)
    steps = args.steps or 200
    batch, seq = 16, 256

model = build_model(cfg)
print(f"training {cfg.arch_id}-family model: "
      f"{model.param_count()/1e6:.1f}M params, {steps} steps, "
      f"batch {batch} x seq {seq}")

tc = TrainConfig(learning_rate=3e-3, total_steps=steps,
                 warmup_steps=max(steps // 10, 1), remat="none",
                 log_every=10)
loader = ShardedLoader(cfg, global_batch=batch, seq_len=seq, seed=0)
res = train(model, tc, loader, num_steps=steps)

first = sum(res.losses[:5]) / 5
last = sum(res.losses[-5:]) / 5
print(f"\nloss: {first:.4f} -> {last:.4f} "
      f"({res.steps_per_sec:.2f} steps/s)")
assert last < first, "loss did not improve"
print("OK: loss improved")
