"""Adaptive edge-cloud serving under a drifting bandwidth trace (Fig. 8).

  PYTHONPATH=src python examples/edge_cloud_serving.py

Builds the full JALAD serving stack (calibration -> ILP engine -> server
with a bandwidth-estimating adaptation controller) and serves a stream of
requests while the network degrades from 1.5 MB/s to 50 KB/s and recovers.
The controller re-solves the decoupling as its bandwidth estimate drifts —
watch the cut move toward the edge as the network gets worse.
"""
import numpy as np

from repro.config import EDGE_TK1, JaladConfig, get_config
from repro.data.synthetic import make_batch
from repro.serving.edge_cloud import build_edge_cloud_server

cfg = get_config("resnet50").reduced()
# A slow TK1 edge keeps the optimum bandwidth-sensitive: on the fast TX2
# default, the byte-minimal late cut wins at every bandwidth of this
# reduced testbed and there would be nothing to adapt.
jalad = JaladConfig(bits_choices=(2, 4, 8), accuracy_drop_budget=0.10,
                    edge=EDGE_TK1)
server, params = build_edge_cloud_server(cfg, jalad, calib_batches=2,
                                         calib_batch_size=8)
print(f"server ready: {len(server.engine.tables.points)} candidate cuts")

# a bandwidth trace that collapses from broadband to a congested link
# and recovers (KB/s). Requests reuse the calibration batch size, so the
# predicted S_i(c)/BW transfer term matches the serving clock's
# blob.nbytes/BW exactly.
trace = [10000, 4000, 1500, 600, 100, 50, 100, 600, 4000, 10000]
batches = [make_batch(cfg, 8, 0, seed=i) for i in range(len(trace))]

print(f"\n{'BW':>8} {'cut':>5} {'bits':>4} {'edge':>8} {'xfer':>8} "
      f"{'cloud':>8} {'total':>8} {'sent':>8}")
for bw_k, batch in zip(trace, batches):
    _, lat = server.serve_batch(batch, bandwidth=bw_k * 1e3)
    print(f"{bw_k:6d}KB {lat.plan_point:5d} {lat.plan_bits:4d} "
          f"{lat.edge_s*1e3:7.1f}m {lat.transfer_s*1e3:7.1f}m "
          f"{lat.cloud_s*1e3:7.1f}m {lat.total_s*1e3:7.1f}m "
          f"{lat.bytes_sent:7d}B")

totals = [l.total_s for l in server.log]
print(f"\nlatency stability: max/min = {max(totals)/min(totals):.1f}x over a "
      f"{max(trace)/min(trace):.0f}x bandwidth swing")
print(f"adaptation events: {len(server.controller.history)}")
