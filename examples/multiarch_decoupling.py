"""JALAD beyond CNNs: decouple every assigned architecture family.

  PYTHONPATH=src python examples/multiarch_decoupling.py

For each family (dense / MoE / SSM / hybrid / VLM / audio, reduced sizes)
this example: picks a mid-network cut, quantizes the boundary hidden state
to 4 bits, runs head+compress+tail, and reports transfer bytes + top-1
agreement with the undecoupled model — the paper's technique as a generic
architecture-level capability.
"""
import jax.numpy as jnp
import numpy as np

from repro.config import get_config
from repro.core.decoupler import DecoupledPlan, DecoupledRunner
from repro.data.synthetic import make_batch
from repro.models.api import build_model

ARCHS = ["olmo-1b", "grok-1-314b", "xlstm-1.3b", "zamba2-2.7b",
         "qwen2-vl-7b", "seamless-m4t-large-v2"]

print(f"{'arch':28s} {'family':7s} {'cut':>4} {'raw B':>9} {'sent B':>8} "
      f"{'ratio':>6} {'agree':>6}")
for arch in ARCHS:
    import jax

    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = {
        k: jnp.asarray(v) for k, v in make_batch(cfg, 2, 24, seed=1).items()
    }
    n = len(model.decoupling_points())
    plan = DecoupledPlan(n // 2, 4, 0.0, 0.0, 0.0)
    runner = DecoupledRunner(model, params, plan)
    logits, sent = runner.run(batch)
    full = model.forward(params, batch)
    agree = float(
        (np.asarray(logits).argmax(-1) == np.asarray(full).argmax(-1)).mean()
    )
    out = model.run_head(params, batch, plan.point)
    boundary = out[0] if isinstance(out, tuple) else out
    raw = np.asarray(boundary).nbytes
    print(f"{arch:28s} {cfg.family:7s} {plan.point:4d} {raw:9d} {sent:8d} "
          f"{raw/sent:5.1f}x {agree:6.2%}")
print("\nJALAD's cut+compress applies to every assigned family "
      "(Sec. Arch-applicability in DESIGN.md)")
