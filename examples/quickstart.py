"""Quickstart: the JALAD pipeline end to end on a small CNN, in five steps.

  PYTHONPATH=src python examples/quickstart.py

1. Build a model (the paper's ResNet testbed, reduced for CPU).
2. Calibrate the accuracy/size predictor tables A_i(c), S_i(c).
3. Build the FMAC latency model with the paper's device constants.
4. Solve the decoupling ILP for the current bandwidth.
5. Run the decoupled inference: edge head -> quantize+Huffman ->
   "transfer" -> dequantize -> cloud tail.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.config import CLOUD_1080TI, EDGE_TK1, JaladConfig, get_config
from repro.core.decoupler import JaladEngine
from repro.core.latency import LatencyModel
from repro.core.predictor import build_tables
from repro.data.synthetic import make_batch
from repro.models.api import build_model

# 1. model -----------------------------------------------------------------
cfg = get_config("resnet50").reduced()
model = build_model(cfg)
params = model.init(jax.random.key(0))
points = model.decoupling_points()
print(f"model: {cfg.arch_id} ({model.param_count()/1e6:.2f}M params, "
      f"{len(points)} decoupling points)")

# 2. predictors -------------------------------------------------------------
bits_choices = [2, 4, 8]
BATCH = 4
calib = [make_batch(cfg, BATCH, 0, seed=i) for i in range(2)]
tables = build_tables(model, params, calib, bits_choices)
print(f"calibrated A_i(c), S_i(c): base accuracy {tables.base_accuracy:.2f}")

# 3. latency model ----------------------------------------------------------
# Same per-batch unit everywhere: S_i(c) is bytes per calibration batch,
# so the FMAC vectors and the raw-input upload are sized for BATCH too.
# The TK1 edge keeps the cut bandwidth-sensitive on this reduced testbed
# (on the fast TX2, the byte-minimal late cut wins at every bandwidth).
lat = LatencyModel(
    model.per_point_fmacs(BATCH), EDGE_TK1, CLOUD_1080TI,
    input_bytes=BATCH * 3 * cfg.image_size ** 2,
)

# 4. decide -----------------------------------------------------------------
jalad = JaladConfig(bits_choices=tuple(bits_choices),
                    accuracy_drop_budget=0.10)
engine = JaladEngine(model, tables, lat, jalad)
for bw in (10e6, 1e6, 50e3):
    plan = engine.decide(bandwidth=bw)
    print(f"BW {bw/1e3:6.0f} KB/s -> cut after {points[plan.point]!r} "
          f"(#{plan.point}), c={plan.bits} bits, "
          f"predicted {plan.predicted_latency*1e3:.2f} ms "
          f"(solved in {plan.solve_ms:.2f} ms)")

# 5. run decoupled ----------------------------------------------------------
# Broadband: the ILP picks an early cloud-heavy cut whose (quantized +
# entropy-coded) interior boundary shows the real compression story.
plan = engine.decide(bandwidth=10e6)
runner = engine.make_runner(params, plan)
batch = make_batch(cfg, BATCH, 0, seed=99)
logits, sent_bytes = runner.run(batch)
full = model.forward(params, batch)
agree = (np.asarray(logits).argmax(-1) == np.asarray(full).argmax(-1)).mean()
raw = model.boundary_bytes(BATCH)[plan.point]
print(f"decoupled inference: sent {sent_bytes} B "
      f"(raw boundary {raw} B, {raw/sent_bytes:.1f}x compression), "
      f"top-1 agreement with the undecoupled model: {agree:.2%}")
